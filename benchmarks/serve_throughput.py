"""Serve-layer throughput: the lookup SERVICE under streaming load.

The paper's §7 multi-thread study (and SOSD after it) makes
throughput-under-parallel-load the decisive metric for learned indexes
in systems.  This benchmark drives `repro.serve.lookup.LookupService` —
async admission, deadline/size micro-batching, sharded fused dispatch —
with a stream of small requests and sweeps

    executor x micro-batch budget x index type x dataset,

emitting one JSON row per cell: achieved lookups/sec, the DECOMPOSED
latency distribution (queue = admission->dispatch, batch = dispatch->
complete, request = end-to-end), batcher occupancy, executor counters
(executable-cache hit rate, in-flight slot depth), and
`verified_vs_core` — the service's positions compared bit-for-bit
against a direct single-device `repro.core` fused lookup on the same
query stream.

The ``executor`` axis is the DESIGN.md §13 comparison: "sync" is the
serial take -> block -> complete reference loop, whose p99 carries every
first-touch trace/compile; "async" is the continuous-batching engine —
pre-compiled executable cache (warmed before serving), launch-without-
blocking double buffering, bounded in-flight slot ring.  Same requests,
same bit-exact results; the p99_request_ms column is the number the
async executor exists to shrink.

``--topology`` adds the DESIGN.md §16 axis: "routed" serves every cell
through the range-routed shard mesh (``SERVE_SHARDS`` per-range indexes,
scatter/gather dispatch), "both" emits routed-vs-broadcast A/B rows —
``per_device_keys`` is the O(batch) -> O(batch/shards) column.

    PYTHONPATH=src python benchmarks/serve_throughput.py
    PYTHONPATH=src python benchmarks/serve_throughput.py --executor async --smoke
    PYTHONPATH=src python benchmarks/serve_throughput.py --topology both --executor async
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python benchmarks/serve_throughput.py --smoke --topology routed

``--smoke`` runs one tiny sync-vs-async cell and exits nonzero if the
async positions diverge from sync by one bit or the warmed executable
cache never hits — the CI tripwire for the §13 parity contract.  The
smoke also exercises the §14 observability contract: it re-runs the
async cell with tracing on, reconciles the trace-derived request p99
against the metrics-snapshot p99 (they must land within one histogram
bucket — same requests, two independent recording paths), measures the
tracing throughput tax, and writes the cell's metrics snapshot to
``benchmarks/results/serve_smoke_metrics.json``.  With
``--check-baseline`` that snapshot is additionally held against the
committed ``benchmarks/baselines/serve_smoke_baseline.json`` with
generous tolerance bands — the perf tripwire that catches a serve-path
p99 regression before it merges.

The smoke also pins the §15 index-health contract: the instrumented
lookup path must be bit-identical to the health-off path and cost a
bounded throughput fraction, the healthy stationary cell must end with
zero alerts firing, and an injected hot-spot skew shift must raise the
``workload_drift`` alert — nonzero exit either way it fails.  Sweep
rows carry the health columns (``disp_p99``, ``bound_utilization_p99``,
``disp_p99_ratio``, ``drift_tv``, ``mean_last_mile_steps``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/serve_throughput.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks import _common as C

#: (max_batch keys per dispatch, keys per client request)
BATCH_POINTS = [(512, 32), (4096, 256)]

#: index types swept, at the shared serving-default hyperparameters
#: (repro.serve.lookup.default_spec — same table the serve driver uses)
INDEX_NAMES = ["rmi", "pgm", "radix_spline"]

#: SERVE_DATASETS trims the sweep (comma-separated) for CI-sized runs
DATASETS = [d for d in os.environ.get(
    "SERVE_DATASETS", "amzn,face,osm,wiki").split(",") if d]

#: dispatch-engine axis (DESIGN.md §13)
EXECUTORS = ["sync", "async"]

#: queries per cell — enough batches for a latency distribution, small
#: enough that the 48-cell sweep stays CPU-container friendly.
N_SERVE_Q = int(os.environ.get("SERVE_Q", min(C.N_QUERIES, 10_000)))


def _run_cell(ds: str, spec, max_batch: int, request_keys: int,
              backend: str = "jnp", executor: str = "sync",
              trace: bool = False, health: bool = True, queries=None,
              shards: int = 1, replicas: int = 1):
    import jax.numpy as jnp
    from repro.serve.lookup import LookupService, LookupServiceConfig

    keys = C.dataset(ds)
    q = C.queries(ds)[:N_SERVE_Q] if queries is None else queries

    t0 = time.perf_counter()
    svc = LookupService(keys, LookupServiceConfig(
        spec=spec.replace(backend=backend),
        max_batch=max_batch, deadline_ms=2.0, executor=executor,
        shards=shards, replicas=replicas,
        trace=trace, health=health))
    build_s = time.perf_counter() - t0

    chunks = [q[i:i + request_keys] for i in range(0, len(q), request_keys)]
    with svc:                       # background flusher (warms when async)
        futs = [svc.submit(c) for c in chunks]
        outs = [f.result(timeout=120.0) for f in futs]
    got = np.concatenate(outs)

    # verify against a direct single-device plan lookup on the JNP
    # backend — cross-backend when the service runs pallas, and reusing
    # the generation's own plan (per-plan compile cache, no re-lowering).
    # A routed generation has no single global plan: verify against the
    # host lower-bound oracle instead (same global-rank contract).
    if shards > 1:
        direct = np.searchsorted(keys, q, side="left").astype(np.int64)
    else:
        direct = np.asarray(
            svc.generation.plan.compile(backend="jnp")(jnp.asarray(q)),
            dtype=np.int64)
    verified = bool(np.array_equal(got, direct))

    snap = svc.metrics.snapshot()
    row = {
        "dataset": ds,
        "index": spec.index,
        "spec": svc.generation.spec.to_dict(),
        "executor": executor,
        "max_batch": max_batch,
        "backend": backend,
        "request_keys": request_keys,
        "n_keys": int(len(keys)),
        "n_queries": int(len(q)),
        "n_shards": svc.dispatcher.n_shards,
        # routed-vs-broadcast A/B columns (DESIGN.md §16): which path the
        # cell dispatched, per-device work (keys per shard lane — O(batch)
        # broadcast, O(batch/shards) routed), and the observed route skew
        "topology": "routed" if shards > 1 else "broadcast",
        "per_device_keys": round(snap["lookups"]
                                 / max(svc.dispatcher.n_shards, 1), 1),
        "route_skew": round(snap["route_skew"], 3),
        "build_s": round(build_s, 4),
        "lookups_per_s": round(snap["lookups_per_s"], 1),
        "mean_batch_ms": round(snap["mean_batch_ms"], 4),
        "p99_batch_ms": round(snap["p99_batch_ms"], 4),
        # latency decomposition (§13 observability): queue + batch ~=
        # request, so a p99 regression names its own culprit
        "p99_queue_ms": round(snap["p99_queue_ms"], 4),
        "mean_request_ms": round(snap["mean_request_ms"], 4),
        "p99_request_ms": round(snap["p99_request_ms"], 4),
        "cache_hit_rate": round(snap["cache_hit_rate"], 4),
        "warm_compiles": snap["warm_compiles"],
        "mean_inflight_slots": round(snap["mean_inflight_slots"], 3),
        "mean_occupancy": round(snap["mean_occupancy"], 4),
        "batches": snap["batches"],
        "verified_vs_core": verified,
    }
    # §15 index-health columns (zeros when the cell ran with health off;
    # the window spans the whole cell — the ring clamps it to capacity)
    h = svc.health_snapshot(window_s=3600.0)
    row.update({
        "disp_p99": round(h.get("disp_p99", 0.0), 1),
        "bound_utilization_p99": round(
            h.get("bound_utilization_p99", 0.0), 4),
        "disp_p99_ratio": round(h.get("disp_p99_ratio", 0.0), 3),
        "drift_tv": round(h.get("drift_tv", 0.0), 4),
        "mean_last_mile_steps": round(
            h.get("mean_last_mile_steps", 0.0), 3),
    })
    return row, got, svc


#: shard count of the routed topology cells (DESIGN.md §16)
N_SHARDS = int(os.environ.get("SERVE_SHARDS", 4))


def run(out_dir: str = "benchmarks/results", backend=None, spec=None,
        autotune=None, executor: str = "both",
        topology: str = "broadcast"):
    """Sweep the service.  ``spec`` pins ONE declarative IndexSpec for
    every cell; ``autotune`` (a byte budget) lets the `spec.Tuner` pick
    the per-dataset spec+backend instead of the serving defaults;
    ``executor`` picks one engine or "both" (the §13 A/B columns);
    ``topology`` picks broadcast dispatch, the range-routed shard mesh
    (``SERVE_SHARDS`` ranges, §16), or "both" (the routed-vs-broadcast
    A/B columns: per-device work, throughput, p99).

    Every row also carries the §14.3 stage-decomposition columns —
    measured predict vs bounded-search ns/lookup for the cell's
    generation, the `analysis.cost_ns` proxy split along the same seam,
    and their ratio — profiled once per (dataset, spec, backend) and
    shared across the batch/executor cells serving that generation."""
    from repro.obs.profiler import profile_generation
    from repro.serve.lookup import default_spec

    backend = backend or C.BACKEND
    executors = EXECUTORS if executor == "both" else [executor]
    topologies = (["broadcast", "routed"] if topology == "both"
                  else [topology])
    rows = []
    stage_cache = {}
    for ds in DATASETS:
        if spec is not None:
            cells = [spec]
        elif autotune is not None:
            res = C.tuned_spec(ds, autotune, names=tuple(INDEX_NAMES),
                               backends=("jnp", "pallas"))
            cells = [res.spec]
        else:
            cells = [default_spec(i) for i in INDEX_NAMES]
        for sp in cells:
            be = sp.backend if (autotune is not None
                                and spec is None) else backend
            for max_batch, request_keys in BATCH_POINTS:
                for ex in executors:
                    for topo in topologies:
                        shards = N_SHARDS if topo == "routed" else 1
                        r, _, svc = _run_cell(ds, sp, max_batch,
                                              request_keys, backend=be,
                                              executor=ex, shards=shards)
                        sk = (ds, sp.index, be)
                        if sk not in stage_cache:
                            # the profiler reads one single-plan
                            # generation: probe a broadcast build (a
                            # routed-only sweep builds one throwaway)
                            if shards == 1:
                                gen = svc.generation
                            else:
                                from repro.serve.lookup import IndexRegistry
                                gen = IndexRegistry().build_and_publish(
                                    sp.replace(backend=be), C.dataset(ds))
                            prof = profile_generation(
                                gen, C.queries(ds)[:N_SERVE_Q])
                            stage_cache[sk] = {
                                k: (round(v, 2)
                                    if isinstance(v, float) else v)
                                for k, v in prof.items()
                                if k.startswith(("stage_", "proxy_",
                                                 "cost_model",
                                                 "avg_width"))}
                        r.update(stage_cache[sk])
                        rows.append(r)
                        print(f"{ds:5s} {r['index']:12s} {ex:5s} "
                              f"{topo:9s} batch={max_batch:5d} "
                              f"{r['lookups_per_s']/1e3:9.1f} klookups/s  "
                              f"p99_req={r['p99_request_ms']:8.2f}ms  "
                              f"dev_keys={r['per_device_keys']:9.0f}  "
                              f"hit={r['cache_hit_rate']:.2f}  occ="
                              f"{r['mean_occupancy']:.2f}  "
                              f"verified={r['verified_vs_core']}",
                              flush=True)
    if executor == "both":
        _print_speedups(rows)
    if topology == "both":
        _print_topology_ab(rows)
    path = os.path.join(out_dir, "serve_throughput.json")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {path}")
    n_bad = sum(not r["verified_vs_core"] for r in rows)
    if n_bad:
        raise SystemExit(f"{n_bad}/{len(rows)} cells NOT verified vs core")
    return rows


def _print_speedups(rows):
    """Per-cell sync/async p99 ratio — the §13 headline column."""
    cells = {}
    for r in rows:
        k = (r["dataset"], r["index"], r["max_batch"])
        cells.setdefault(k, {})[r["executor"]] = r
    ratios = []
    for (ds, ix, mb), pair in sorted(cells.items()):
        if "sync" not in pair or "async" not in pair:
            continue
        p_sync = pair["sync"]["p99_request_ms"]
        p_async = pair["async"]["p99_request_ms"]
        ratio = p_sync / p_async if p_async else float("inf")
        ratios.append(ratio)
        print(f"  p99 speedup {ds:5s} {ix:12s} batch={mb:5d}: "
              f"{p_sync:8.2f}ms -> {p_async:7.2f}ms  ({ratio:5.1f}x)",
              flush=True)
    if ratios:
        print(f"  p99 speedup median: {np.median(ratios):.1f}x  "
              f"(min {min(ratios):.1f}x, max {max(ratios):.1f}x)",
              flush=True)


def _print_topology_ab(rows):
    """Routed-vs-broadcast A/B per cell (§16): per-device work,
    throughput, and request p99 side by side."""
    cells = {}
    for r in rows:
        k = (r["dataset"], r["index"], r["max_batch"], r["executor"])
        cells.setdefault(k, {})[r["topology"]] = r
    t_ratios, p_ratios = [], []
    for (ds, ix, mb, ex), pair in sorted(cells.items()):
        if "broadcast" not in pair or "routed" not in pair:
            continue
        b, rt = pair["broadcast"], pair["routed"]
        t_ratio = (rt["lookups_per_s"] / b["lookups_per_s"]
                   if b["lookups_per_s"] else float("inf"))
        p_ratio = (b["p99_request_ms"] / rt["p99_request_ms"]
                   if rt["p99_request_ms"] else float("inf"))
        t_ratios.append(t_ratio)
        p_ratios.append(p_ratio)
        print(f"  routed A/B {ds:5s} {ix:12s} {ex:5s} batch={mb:5d}: "
              f"dev_keys {b['per_device_keys']:9.0f} -> "
              f"{rt['per_device_keys']:9.0f}  "
              f"tput {t_ratio:5.2f}x  p99 {p_ratio:5.2f}x", flush=True)
    if t_ratios:
        print(f"  routed throughput median {np.median(t_ratios):.2f}x, "
              f"p99 speedup median {np.median(p_ratios):.2f}x "
              f"over broadcast", flush=True)


#: committed perf baseline + the snapshot each smoke writes beside the
#: other benchmark results
BASELINE_PATH = "benchmarks/baselines/serve_smoke_baseline.json"
SMOKE_METRICS_PATH = "benchmarks/results/serve_smoke_metrics.json"
ROUTED_SMOKE_METRICS_PATH = \
    "benchmarks/results/serve_smoke_routed_metrics.json"

#: tolerance bands for --check-baseline.  Deliberately generous: CI
#: containers vary widely in CPU quality, and the tripwire exists to
#: catch order-of-magnitude serve-path regressions (an accidental
#: recompile per batch, a lock on the hot path), not 10% noise.
BASELINE_MAX_P99_RATIO = 5.0       # p99_request_ms may grow at most 5x
BASELINE_MIN_THROUGHPUT_RATIO = 0.2   # lookups/s may drop at most 5x

#: hard ceiling for the tracing throughput tax in the smoke — the §14
#: target is <5%, but one tiny cell is noisy, so the EXIT threshold
#: leaves headroom for scheduler jitter while still catching a
#: pathological recorder (e.g. one that serializes the dispatch path).
TRACE_OVERHEAD_EXIT_FRAC = 0.50

#: same shape of ceiling for the §15 health instrumentation tax
#: (device-reduced stats are O(buckets)/batch on the host; a pathological
#: implementation that ships O(batch) or forces a sync would blow this).
HEALTH_OVERHEAD_EXIT_FRAC = 0.50


def _reconcile_trace(svc, row) -> dict:
    """§14 acceptance: the request p99 derived from raw trace spans and
    the p99 the metrics histogram reports must land within ONE histogram
    bucket of each other — same requests, two independent recording
    paths (deque of spans vs log-bucketed counts)."""
    from repro.obs.trace import SpanRecorder
    from repro.obs.windows import LatencyHistogram

    trace = svc.recorder.to_chrome()
    lats = list(SpanRecorder.request_latencies_s(trace).values())
    if not lats:
        raise SystemExit("traced smoke produced no request spans")
    trace_p99_s = float(np.quantile(np.asarray(lats), 0.99,
                                    method="higher"))
    hist = LatencyHistogram()
    b_trace = hist.bucket_index(trace_p99_s)
    b_snap = hist.bucket_index(row["p99_request_ms"] / 1e3)
    print(f"  trace p99 {trace_p99_s*1e3:.2f}ms (bucket {b_trace})  vs  "
          f"snapshot p99 {row['p99_request_ms']:.2f}ms (bucket {b_snap})  "
          f"over {len(lats)} request spans", flush=True)
    if abs(b_trace - b_snap) > 1:
        raise SystemExit(
            f"trace-derived p99 ({trace_p99_s*1e3:.2f}ms, bucket "
            f"{b_trace}) and snapshot p99 ({row['p99_request_ms']:.2f}ms, "
            f"bucket {b_snap}) disagree by more than one histogram bucket")
    return {"trace_p99_ms": trace_p99_s * 1e3,
            "trace_p99_bucket": b_trace, "snapshot_p99_bucket": b_snap,
            "trace_request_spans": len(lats)}


def _check_baseline(metrics: dict) -> None:
    """Hold this smoke's snapshot against the committed baseline; exit
    nonzero on a p99 or throughput regression beyond the bands."""
    if not os.path.exists(BASELINE_PATH):
        raise SystemExit(f"--check-baseline: no baseline at "
                         f"{BASELINE_PATH} (run a smoke and commit "
                         f"{SMOKE_METRICS_PATH} there)")
    with open(BASELINE_PATH) as f:
        base = json.load(f)
    p99, b_p99 = metrics["p99_request_ms"], base["p99_request_ms"]
    tput, b_tput = metrics["lookups_per_s"], base["lookups_per_s"]
    p99_ratio = p99 / b_p99 if b_p99 else float("inf")
    tput_ratio = tput / b_tput if b_tput else 0.0
    print(f"  baseline: p99 {p99:.2f}ms vs {b_p99:.2f}ms "
          f"({p99_ratio:.2f}x, limit {BASELINE_MAX_P99_RATIO:.1f}x); "
          f"throughput {tput/1e3:.1f} vs {b_tput/1e3:.1f} klookups/s "
          f"({tput_ratio:.2f}x, floor {BASELINE_MIN_THROUGHPUT_RATIO:.1f}x)",
          flush=True)
    fails = []
    if p99_ratio > BASELINE_MAX_P99_RATIO:
        fails.append(f"p99_request_ms regressed {p99_ratio:.1f}x over "
                     f"baseline (limit {BASELINE_MAX_P99_RATIO:.1f}x)")
    if tput_ratio < BASELINE_MIN_THROUGHPUT_RATIO:
        fails.append(f"lookups_per_s fell to {tput_ratio:.2f}x of "
                     f"baseline (floor {BASELINE_MIN_THROUGHPUT_RATIO:.1f}x)")
    if fails:
        raise SystemExit("perf baseline tripwire: " + "; ".join(fails))
    print("  baseline check ok", flush=True)


def smoke(backend=None, executor: str = "async",
          check_baseline: bool = False) -> None:
    """One tiny A/B cell, CI tripwire semantics: exit NONZERO when
    (a) the async executor's positions differ from the sync executor's
    by even one bit, (b) the warmed executable cache never hits under
    serving traffic, (c) either engine diverges from the direct
    `repro.core` lookup, (d) a traced re-run's span-derived request p99
    disagrees with the metrics-snapshot p99 by more than one histogram
    bucket, (e) tracing costs a pathological fraction of throughput,
    (f) with ``check_baseline``, the snapshot regresses past the
    committed baseline's tolerance bands, or — the §15 health contract —
    (g) health instrumentation changes any position bit or costs a
    pathological throughput fraction, (h) any alert fires on the
    healthy stationary cell, or (i) an injected hot-spot skew shift
    fails to raise the ``workload_drift`` alert."""
    from repro.serve.lookup import default_spec

    backend = backend or C.BACKEND
    sp = default_spec("rmi")
    row_s, got_s, _ = _run_cell("amzn", sp, 512, 32, backend=backend,
                                executor="sync")
    row_a, got_a, _ = _run_cell("amzn", sp, 512, 32, backend=backend,
                                executor=executor)
    for tag, row in (("sync", row_s), (executor, row_a)):
        print(f"  {tag:5s}: p99_req={row['p99_request_ms']:8.2f}ms  "
              f"p99_queue={row['p99_queue_ms']:8.2f}ms  "
              f"hit={row['cache_hit_rate']:.2f}  "
              f"verified={row['verified_vs_core']}", flush=True)
    if not np.array_equal(got_s, got_a):
        raise SystemExit(
            f"{executor} executor DIVERGED from sync: "
            f"{int(np.sum(got_s != got_a))}/{got_s.size} positions differ")
    if not (row_s["verified_vs_core"] and row_a["verified_vs_core"]):
        raise SystemExit("service positions diverged from repro.core")
    if executor == "async" and row_a["cache_hit_rate"] <= 0.0:
        raise SystemExit("async executable cache NEVER hit after warm-up")

    # -- §14 observability contract: traced re-run of the same cell ----
    # The first async cell pays every process-level JAX first-touch, so
    # compare traced vs untraced on WARM re-runs (both benefit equally
    # from the in-process compile caches primed above).
    row_w, got_w, svc_w = _run_cell("amzn", sp, 512, 32, backend=backend,
                                    executor=executor)
    row_t, got_t, svc_t = _run_cell("amzn", sp, 512, 32, backend=backend,
                                    executor=executor, trace=True)
    if not (np.array_equal(got_a, got_t) and np.array_equal(got_a, got_w)):
        raise SystemExit("tracing changed the results — recorder is not "
                         "observation-only")
    recon = _reconcile_trace(svc_t, row_t)
    overhead = (1.0 - row_t["lookups_per_s"] / row_w["lookups_per_s"]
                if row_w["lookups_per_s"] else 0.0)
    print(f"  tracing overhead: {overhead*100:+.1f}% throughput "
          f"({row_w['lookups_per_s']/1e3:.1f} -> "
          f"{row_t['lookups_per_s']/1e3:.1f} klookups/s; "
          f"target <5%, exit threshold "
          f"{TRACE_OVERHEAD_EXIT_FRAC*100:.0f}%)", flush=True)
    if overhead > TRACE_OVERHEAD_EXIT_FRAC:
        raise SystemExit(f"tracing cost {overhead*100:.0f}% of throughput "
                         f"— recorder is on the critical path")

    # -- §15 index-health contract -------------------------------------
    # (g) instrumentation must be invisible in the results and cheap:
    # health-off re-run of the same warm cell, bit-compared
    row_h0, got_h0, _ = _run_cell("amzn", sp, 512, 32, backend=backend,
                                  executor=executor, health=False)
    if not np.array_equal(got_a, got_h0):
        raise SystemExit("health instrumentation changed the results — "
                         "the instrumented executable is not the same "
                         "lookup")
    h_overhead = (1.0 - row_w["lookups_per_s"] / row_h0["lookups_per_s"]
                  if row_h0["lookups_per_s"] else 0.0)
    print(f"  health overhead: {h_overhead*100:+.1f}% throughput "
          f"({row_h0['lookups_per_s']/1e3:.1f} -> "
          f"{row_w['lookups_per_s']/1e3:.1f} klookups/s; exit threshold "
          f"{HEALTH_OVERHEAD_EXIT_FRAC*100:.0f}%)", flush=True)
    if h_overhead > HEALTH_OVERHEAD_EXIT_FRAC:
        raise SystemExit(f"health stats cost {h_overhead*100:.0f}% of "
                         f"throughput — the reduction is not O(buckets)")
    # (h) the healthy stationary cell must be alert-silent
    svc_w.check_alerts(window_s=3600.0)
    firing = svc_w.alerts.firing()
    if firing:
        raise SystemExit(f"health smoke: alerts firing on a healthy "
                         f"stationary run: {firing}")
    print(f"  health: healthy cell silent (disp p99 {row_w['disp_p99']:.0f}"
          f", {row_w['disp_p99_ratio']:.2f}x build, drift TV "
          f"{row_w['drift_tv']:.3f})", flush=True)
    # (i) an injected hot-spot skew shift must raise workload_drift
    keys = C.dataset("amzn")
    hot = np.random.default_rng(0).choice(
        keys[:max(1, len(keys) // 64)], size=row_w["n_queries"])
    row_d, _, svc_d = _run_cell("amzn", sp, 512, 32, backend=backend,
                                executor=executor, queries=hot)
    svc_d.check_alerts(window_s=3600.0)
    if "workload_drift" not in svc_d.alerts.firing():
        raise SystemExit(
            f"injected hot-spot skew did NOT raise workload_drift "
            f"(drift_tv {row_d['drift_tv']:.3f})")
    print(f"  health: injected skew raised workload_drift "
          f"(drift_tv {row_d['drift_tv']:.3f})", flush=True)

    # snapshot the WARM untraced cell — the steady-state number the
    # committed baseline pins, free of process-level first-touch cost
    metrics = {
        "cell": {"dataset": "amzn", "index": sp.index, "max_batch": 512,
                 "request_keys": 32, "executor": executor,
                 "backend": backend, "n_queries": row_w["n_queries"]},
        "lookups_per_s": row_w["lookups_per_s"],
        "p99_request_ms": row_w["p99_request_ms"],
        "p99_queue_ms": row_w["p99_queue_ms"],
        "p99_batch_ms": row_w["p99_batch_ms"],
        "mean_request_ms": row_w["mean_request_ms"],
        "cache_hit_rate": row_w["cache_hit_rate"],
        "trace_overhead_frac": round(overhead, 4),
        "health_overhead_frac": round(h_overhead, 4),
        "disp_p99": row_w["disp_p99"],
        "bound_utilization_p99": row_w["bound_utilization_p99"],
        "disp_p99_ratio": row_w["disp_p99_ratio"],
        "drift_tv": row_w["drift_tv"],
        **{k: (round(v, 4) if isinstance(v, float) else v)
           for k, v in recon.items()},
    }
    os.makedirs(os.path.dirname(SMOKE_METRICS_PATH), exist_ok=True)
    with open(SMOKE_METRICS_PATH, "w") as f:
        json.dump(metrics, f, indent=1)
    print(f"  wrote {SMOKE_METRICS_PATH}", flush=True)
    if check_baseline:
        _check_baseline(metrics)
    print(f"smoke ok: {executor} bit-identical to sync "
          f"({got_s.size} positions), cache hit rate "
          f"{row_a['cache_hit_rate']:.2f}, trace p99 reconciled "
          f"(|Δbucket| = "
          f"{abs(recon['trace_p99_bucket'] - recon['snapshot_p99_bucket'])})",
          flush=True)


def routed_smoke(backend=None, check_baseline: bool = False,
                 shards: int = 0) -> None:
    """Routed-topology CI tripwire (DESIGN.md §16), exit NONZERO when:
    (a) routed dispatch (sync OR async) differs from broadcast sync by
    even one bit, on ANY index cell, (b) either diverges from the direct
    `repro.core` lookup, (c) `/health.json` is missing a per-shard
    health record (or `/metrics.json` / the Prometheus text is missing
    the ``shard``-labelled load rows), (d) the per-bucket host staging
    buffers keep allocating batch after batch (the pinned-staging
    contract), or (e) with ``check_baseline``, the routed cell regresses
    past the committed baseline's ``routed`` bands.  Run it forced
    multi-device (``XLA_FLAGS=--xla_force_host_platform_device_count=N``)
    to exercise real shard placement."""
    import urllib.request

    import jax

    from repro.obs.export import MetricsServer
    from repro.serve.lookup import default_spec

    backend = backend or C.BACKEND
    shards = shards or N_SHARDS
    print(f"routed smoke: {shards} shards over {jax.device_count()} "
          f"device(s)", flush=True)

    svc_keep, row_keep, row_bcast = None, None, None
    for ix in INDEX_NAMES:
        sp = default_spec(ix)
        row_b, got_b, _ = _run_cell("amzn", sp, 512, 32, backend=backend,
                                    executor="sync")
        row_rs, got_rs, _ = _run_cell("amzn", sp, 512, 32, backend=backend,
                                      executor="sync", shards=shards)
        row_ra, got_ra, svc = _run_cell("amzn", sp, 512, 32,
                                        backend=backend, executor="async",
                                        shards=shards)
        for tag, got in (("sync", got_rs), ("async", got_ra)):
            if not np.array_equal(got_b, got):
                raise SystemExit(
                    f"routed {tag} dispatch DIVERGED from broadcast on "
                    f"{ix}: {int(np.sum(got_b != got))}/{got_b.size} "
                    f"positions differ")
        if not (row_rs["verified_vs_core"] and row_ra["verified_vs_core"]):
            raise SystemExit(f"routed positions diverged from repro.core "
                             f"on {ix}")
        print(f"  {ix:12s}: routed == broadcast ({got_b.size} positions, "
              f"sync+async), dev_keys "
              f"{row_b['per_device_keys']:.0f} -> "
              f"{row_ra['per_device_keys']:.0f}, "
              f"skew {row_ra['route_skew']:.2f}", flush=True)
        if ix == INDEX_NAMES[0]:
            # same-executor broadcast reference for the A/B section
            row_ba, got_ba, _ = _run_cell("amzn", sp, 512, 32,
                                          backend=backend,
                                          executor="async")
            if not np.array_equal(got_b, got_ba):
                raise SystemExit("broadcast async diverged from sync")
            svc_keep, row_keep, row_bcast = svc, row_ra, row_ba
        else:
            svc.stop()

    # -- per-shard observability over the real HTTP surface ------------
    svc = svc_keep
    n_shards = svc.dispatcher.n_shards
    with MetricsServer(svc) as srv:
        def _get(path):
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}{path}") as resp:
                return resp.read().decode()
        hdoc = json.loads(_get("/health.json"))
        mdoc = json.loads(_get("/metrics.json"))
        prom = _get("/metrics")
    seen = {g["shard"] for g in hdoc.get("generations", [])
            if "shard" in g}
    if seen != set(range(n_shards)):
        raise SystemExit(f"/health.json missing per-shard health "
                         f"records: got shards {sorted(seen)}, want "
                         f"0..{n_shards - 1}")
    shard_rows = mdoc.get("per_shard", [])
    if {r["shard"] for r in shard_rows} != set(range(n_shards)):
        raise SystemExit("/metrics.json per_shard rows incomplete: "
                         + json.dumps(shard_rows))
    if 'repro_lookup_shard_keys{shard="0"}' not in prom:
        raise SystemExit("Prometheus text missing shard-labelled "
                         "families")
    print(f"  per-shard surfaces ok ({n_shards} shard records in "
          f"/health.json; shard-labelled /metrics + /metrics.json)",
          flush=True)

    # -- pinned host staging: steady-state batches must not allocate ---
    q = C.queries("amzn")[:N_SERVE_Q]
    chunks = [q[i:i + 32] for i in range(0, len(q), 32)]

    def _wave():
        with svc:
            for f in [svc.submit(c) for c in chunks]:
                f.result(timeout=120.0)
    _wave()                                # settle any leftover buckets
    a0, h0 = svc.dispatcher.staging_allocs, svc.dispatcher.staging_hits
    _wave()
    a1, h1 = svc.dispatcher.staging_allocs, svc.dispatcher.staging_hits
    if a1 != a0:
        raise SystemExit(f"per-batch host staging allocation grew under "
                         f"steady traffic: {a0} -> {a1} buffers")
    print(f"  staging steady: {a1} pinned buffers, "
          f"{h1 - h0} reuses over the assertion wave", flush=True)
    svc.stop()

    metrics = {
        "cell": {"dataset": "amzn", "index": INDEX_NAMES[0],
                 "max_batch": 512, "request_keys": 32,
                 "executor": "async", "backend": backend,
                 "shards": n_shards,
                 "n_queries": row_keep["n_queries"]},
        "routed": {
            "lookups_per_s": row_keep["lookups_per_s"],
            "p99_request_ms": row_keep["p99_request_ms"],
            "per_device_keys": row_keep["per_device_keys"],
            "route_skew": row_keep["route_skew"],
            "cache_hit_rate": row_keep["cache_hit_rate"],
        },
        "broadcast": {
            "lookups_per_s": row_bcast["lookups_per_s"],
            "p99_request_ms": row_bcast["p99_request_ms"],
            "per_device_keys": row_bcast["per_device_keys"],
        },
    }
    os.makedirs(os.path.dirname(ROUTED_SMOKE_METRICS_PATH), exist_ok=True)
    with open(ROUTED_SMOKE_METRICS_PATH, "w") as f:
        json.dump(metrics, f, indent=1)
    print(f"  wrote {ROUTED_SMOKE_METRICS_PATH}", flush=True)
    if check_baseline:
        with open(BASELINE_PATH) as f:
            base = json.load(f)
        rb = base.get("routed")
        if rb is None:
            raise SystemExit(f"--check-baseline: no 'routed' section in "
                             f"{BASELINE_PATH}")
        got, want = metrics["routed"], rb
        p99_ratio = (got["p99_request_ms"] / want["p99_request_ms"]
                     if want["p99_request_ms"] else float("inf"))
        tput_ratio = (got["lookups_per_s"] / want["lookups_per_s"]
                      if want["lookups_per_s"] else 0.0)
        print(f"  routed baseline: p99 {p99_ratio:.2f}x (limit "
              f"{BASELINE_MAX_P99_RATIO:.1f}x), throughput "
              f"{tput_ratio:.2f}x (floor "
              f"{BASELINE_MIN_THROUGHPUT_RATIO:.1f}x)", flush=True)
        fails = []
        if p99_ratio > BASELINE_MAX_P99_RATIO:
            fails.append(f"routed p99_request_ms regressed "
                         f"{p99_ratio:.1f}x")
        if tput_ratio < BASELINE_MIN_THROUGHPUT_RATIO:
            fails.append(f"routed lookups_per_s fell to "
                         f"{tput_ratio:.2f}x")
        if fails:
            raise SystemExit("routed perf baseline tripwire: "
                             + "; ".join(fails))
        print("  routed baseline check ok", flush=True)
    print(f"routed smoke ok: {len(INDEX_NAMES)} index cells bit-identical "
          f"to broadcast on sync+async over {jax.device_count()} "
          f"device(s)", flush=True)


RETUNE_SMOKE_METRICS_PATH = \
    "benchmarks/results/serve_smoke_retune_metrics.json"


def retune_smoke(backend=None, store_dir=None) -> None:
    """Self-driving-tuning CI tripwire (DESIGN.md §17): a deliberately
    MIS-TUNED incumbent under drift-injected hot-spot traffic, A/B'd
    against a no-retune arm.  Exit NONZERO when (a) the shadow retuner
    never reaches a verified hot-swap through the real alert path
    (workload_drift firing -> hysteresis -> tune -> verify -> swap),
    (b) any position bit diverges from the sorted-array oracle at ANY
    point in either arm — before, across, or after the swap, (c) the
    retuned arm's post-swap windowed request p99 is not below the
    no-retune arm's over the same measurement phase, or (d) a second
    service lifetime on the SAME artifact store re-runs the ladder
    sweep instead of hot-swapping straight from the cached spec."""
    import tempfile

    from repro.autotune import AutotuneConfig
    from repro.core.spec import IndexSpec, Tuner
    from repro.serve.lookup import LookupService, LookupServiceConfig

    backend = backend or C.BACKEND
    keys = C.dataset("amzn")
    # mis-tuned on purpose, on BOTH §17 axes: a full-sample btree
    # (stores every key, ~3.2MB — busts the 512KB serving budget below)
    # with fanout 2048, whose two-level descent scans ~4100 node keys
    # per lookup where the ladder's fanout-128 rungs scan ~390 — the
    # spec a stale tuning run (or a careless operator) strands a
    # budget-constrained service on.  The retuner must land a verified
    # swap onto a budgeted ladder rung that serves ~10x fewer node
    # bytes per lookup.
    mis = IndexSpec("btree", {"sample": 1, "fanout": 2048},
                    backend=backend).validated()
    rng = np.random.default_rng(0)
    # full-batch requests at a saturating rate: the A/B p99 must be
    # decided by per-batch DEVICE compute (where window width bites),
    # not by deadline waits or host admission overhead
    hot = rng.choice(keys[:max(1, len(keys) // 64)],
                     size=max(N_SERVE_Q, 65536)).astype(np.uint64)
    chunks = [hot[i:i + 16384] for i in range(0, len(hot), 16384)]
    wants = [np.searchsorted(keys, c, side="left").astype(np.int64)
             for c in chunks]
    store_dir = store_dir or tempfile.mkdtemp(prefix="retune_smoke_")

    def _mk(at_cfg):
        return LookupService(keys, LookupServiceConfig(
            spec=mis, max_batch=16384, deadline_ms=0.5, executor="async",
            autotune=at_cfg))

    div = {"rt": 0, "base": 0, "rt2": 0}

    def _wave(svc, tag):
        futs = [svc.submit(c) for c in chunks]
        for f, want in zip(futs, wants):
            got = np.asarray(f.result(timeout=120.0), dtype=np.int64)
            div[tag] += int(np.count_nonzero(got != want))

    def _drive(svc, tag):
        """Drift phase -> poll-to-swap (when a retuner is attached) ->
        settle.  Returns the last retuner decision (None on the
        no-retune arm)."""
        decision = None
        for _ in range(2):              # drift phase: fill the window
            _wave(svc, tag)
        if svc.autotune is not None:
            deadline = time.perf_counter() + 60.0
            while time.perf_counter() < deadline:
                d = svc.autotune.poll_once()
                if d is not None:
                    decision = d
                if d is not None and d["action"] == "swapped":
                    break
                _wave(svc, tag)         # keep the alert window populated
        else:
            for _ in range(2):          # keep the arms' phases aligned
                _wave(svc, tag)
        svc.warm_wait()                 # let the post-swap re-warm finish
        for _ in range(2):              # settle: pay any remaining
            _wave(svc, tag)             # compiles outside the window
        return decision

    def _phase_p99(svc, tag):
        t_mark = time.perf_counter()
        for _ in range(6):
            _wave(svc, tag)
        w = svc.metrics.windowed(time.perf_counter() - t_mark + 1e-3)
        return w["p99_ms"]

    # budgeted search, the paper's tuning contract: the byte cap keeps
    # the ladder off giant model tables whose gather cost the probe
    # proxy does not price (it is also part of the artifact-store key)
    at_cfg = AutotuneConfig(store_dir=store_dir, hysteresis_s=0.0,
                            cooldown_s=0.0, min_win=0.05,
                            tuner=Tuner(names=("btree",), max_configs=8,
                                        backends=(backend,),
                                        max_bytes=512 * 1024))
    # both arms live at once, measurement phases INTERLEAVED: each
    # phase pair samples the same machine conditions, so background
    # load drifting over the run cancels in the comparison instead of
    # landing entirely on whichever arm ran second (the idle arm's
    # executor just blocks on an empty queue).  One phase's p99 is the
    # max of a handful of bursts — scheduler noise — so the arms are
    # compared on the median across phases.
    svc_rt = _mk(at_cfg)
    svc_base = _mk(None)
    with svc_rt, svc_base:
        decision = _drive(svc_rt, "rt")
        _drive(svc_base, "base")
        p99s_rt, p99s_base = [], []
        for _ in range(5):
            p99s_rt.append(_phase_p99(svc_rt, "rt"))
            p99s_base.append(_phase_p99(svc_base, "base"))
    retuner = svc_rt.autotune
    p99_rt = float(np.median(p99s_rt))
    p99_base = float(np.median(p99s_base))
    div_rt, div_base = div["rt"], div["base"]

    if decision is None or decision["action"] != "swapped":
        raise SystemExit(f"retune smoke: no verified swap happened "
                         f"(last decision: {decision})")
    if decision["verify"]["divergent"] != 0:
        raise SystemExit(f"retune smoke: swap published with divergent "
                         f"bits: {decision['verify']}")
    if div_rt or div_base:
        raise SystemExit(f"retune smoke: served positions diverged from "
                         f"oracle (retune arm {div_rt}, no-retune arm "
                         f"{div_base} bits)")
    print(f"  swap [{decision.get('basis', 'cost')}]: "
          f"{decision['incumbent']['specs'][0]} "
          f"(score {decision['incumbent']['score']}) -> "
          f"{decision['candidate']['specs'][0]} "
          f"(score {decision['candidate']['score']}), verified on "
          f"{decision['verify']['n']} replayed queries, 0 divergent",
          flush=True)
    print(f"  post-swap windowed p99 (median of 5 interleaved phases): "
          f"retuned {p99_rt:.2f}ms vs no-retune {p99_base:.2f}ms",
          flush=True)
    if p99_rt >= p99_base:
        raise SystemExit(
            f"retune smoke: retuned arm's post-swap p99 "
            f"({p99_rt:.2f}ms) did not beat the no-retune arm "
            f"({p99_base:.2f}ms)")

    # -- second lifetime on the same store: swap WITHOUT a sweep -------
    svc2 = _mk(at_cfg)
    with svc2:
        decision2 = _drive(svc2, "rt2")
    retuner2 = svc2.autotune
    div2 = div["rt2"]
    if decision2 is None or decision2["action"] != "swapped":
        raise SystemExit(f"retune smoke: second lifetime did not swap "
                         f"(last decision: {decision2})")
    if not decision2.get("cache_hit") or retuner2.n_sweeps != 0:
        raise SystemExit(
            f"retune smoke: second lifetime re-ran the ladder sweep "
            f"(cache_hit={decision2.get('cache_hit')}, "
            f"sweeps={retuner2.n_sweeps}) — artifact store missed")
    if div2:
        raise SystemExit(f"retune smoke: second lifetime diverged from "
                         f"oracle ({div2} bits)")
    print(f"  warm restart: swap from cached artifact "
          f"(cache_hit=True, sweeps=0, "
          f"store {retuner2.store.stats()})", flush=True)

    metrics = {
        "cell": {"dataset": "amzn", "incumbent": mis.to_dict(),
                 "backend": backend, "n_queries": int(len(hot))},
        "swap": {"candidate": decision["candidate"],
                 "incumbent_score": decision["incumbent"]["score"],
                 "basis": decision.get("basis", "cost"),
                 "verify_n": decision["verify"]["n"]},
        "p99_ms_retuned": round(p99_rt, 4),
        "p99_ms_no_retune": round(p99_base, 4),
        "second_run_cache_hit": True,
    }
    os.makedirs(os.path.dirname(RETUNE_SMOKE_METRICS_PATH), exist_ok=True)
    with open(RETUNE_SMOKE_METRICS_PATH, "w") as f:
        json.dump(metrics, f, indent=1)
    print(f"  wrote {RETUNE_SMOKE_METRICS_PATH}", flush=True)
    print(f"retune smoke ok: drift -> verified hot-swap -> p99 "
          f"{p99_base:.2f}ms -> {p99_rt:.2f}ms, "
          f"restart served from the artifact store", flush=True)


if __name__ == "__main__":
    _ns = C.bench_args()
    _ap = argparse.ArgumentParser(add_help=False)
    _ap.add_argument("--executor", choices=("sync", "async", "both"),
                     default="both")
    _ap.add_argument("--topology",
                     choices=("broadcast", "routed", "both"),
                     default="broadcast",
                     help="dispatch topology axis (DESIGN.md §16): "
                          "broadcast, range-routed shard mesh "
                          "(SERVE_SHARDS ranges), or both (A/B rows); "
                          "with --smoke, 'routed' runs the routed parity "
                          "+ per-shard observability tripwire")
    _ap.add_argument("--check-baseline", action="store_true",
                     help="hold the smoke metrics snapshot against "
                          f"{BASELINE_PATH} (nonzero exit on regression)")
    _ap.add_argument("--retune-smoke", action="store_true",
                     help="self-driving-tuning tripwire (DESIGN.md §17): "
                          "mis-tuned incumbent + drift-injected traffic "
                          "must reach a verified hot-swap that beats the "
                          "no-retune arm's p99, bit-exact throughout, "
                          "and a warm restart must reuse the artifact "
                          "store instead of re-sweeping")
    _opts = _ap.parse_known_args()[0]
    _ex = _opts.executor
    if _opts.retune_smoke:
        retune_smoke(backend=_ns.backend)
    elif _ns.smoke:
        if _opts.topology == "routed":
            routed_smoke(backend=_ns.backend,
                         check_baseline=_opts.check_baseline)
        else:
            smoke(backend=_ns.backend,
                  executor="async" if _ex == "both" else _ex,
                  check_baseline=_opts.check_baseline)
    else:
        run(backend=_ns.backend, spec=_ns.spec, autotune=_ns.autotune,
            executor=_ex, topology=_opts.topology)
