"""Serve-layer throughput: the lookup SERVICE under streaming load.

The paper's §7 multi-thread study (and SOSD after it) makes
throughput-under-parallel-load the decisive metric for learned indexes
in systems.  This benchmark drives `repro.serve.lookup.LookupService` —
async admission, deadline/size micro-batching, sharded fused dispatch —
with a stream of small requests and sweeps

    executor x micro-batch budget x index type x dataset,

emitting one JSON row per cell: achieved lookups/sec, the DECOMPOSED
latency distribution (queue = admission->dispatch, batch = dispatch->
complete, request = end-to-end), batcher occupancy, executor counters
(executable-cache hit rate, in-flight slot depth), and
`verified_vs_core` — the service's positions compared bit-for-bit
against a direct single-device `repro.core` fused lookup on the same
query stream.

The ``executor`` axis is the DESIGN.md §13 comparison: "sync" is the
serial take -> block -> complete reference loop, whose p99 carries every
first-touch trace/compile; "async" is the continuous-batching engine —
pre-compiled executable cache (warmed before serving), launch-without-
blocking double buffering, bounded in-flight slot ring.  Same requests,
same bit-exact results; the p99_request_ms column is the number the
async executor exists to shrink.

    PYTHONPATH=src python benchmarks/serve_throughput.py
    PYTHONPATH=src python benchmarks/serve_throughput.py --executor async --smoke

``--smoke`` runs one tiny sync-vs-async cell and exits nonzero if the
async positions diverge from sync by one bit or the warmed executable
cache never hits — the CI tripwire for the §13 parity contract.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/serve_throughput.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks import _common as C

#: (max_batch keys per dispatch, keys per client request)
BATCH_POINTS = [(512, 32), (4096, 256)]

#: index types swept, at the shared serving-default hyperparameters
#: (repro.serve.lookup.default_spec — same table the serve driver uses)
INDEX_NAMES = ["rmi", "pgm", "radix_spline"]

DATASETS = ["amzn", "face", "osm", "wiki"]

#: dispatch-engine axis (DESIGN.md §13)
EXECUTORS = ["sync", "async"]

#: queries per cell — enough batches for a latency distribution, small
#: enough that the 48-cell sweep stays CPU-container friendly.
N_SERVE_Q = int(os.environ.get("SERVE_Q", min(C.N_QUERIES, 10_000)))


def _run_cell(ds: str, spec, max_batch: int, request_keys: int,
              backend: str = "jnp", executor: str = "sync"):
    import jax.numpy as jnp
    from repro.serve.lookup import LookupService, LookupServiceConfig

    keys = C.dataset(ds)
    q = C.queries(ds)[:N_SERVE_Q]

    t0 = time.perf_counter()
    svc = LookupService(keys, LookupServiceConfig(
        spec=spec.replace(backend=backend),
        max_batch=max_batch, deadline_ms=2.0, executor=executor))
    build_s = time.perf_counter() - t0

    chunks = [q[i:i + request_keys] for i in range(0, len(q), request_keys)]
    with svc:                       # background flusher (warms when async)
        futs = [svc.submit(c) for c in chunks]
        outs = [f.result(timeout=120.0) for f in futs]
    got = np.concatenate(outs)

    # verify against a direct single-device plan lookup on the JNP
    # backend — cross-backend when the service runs pallas, and reusing
    # the generation's own plan (per-plan compile cache, no re-lowering)
    direct = np.asarray(
        svc.generation.plan.compile(backend="jnp")(jnp.asarray(q)),
        dtype=np.int64)
    verified = bool(np.array_equal(got, direct))

    snap = svc.metrics.snapshot()
    row = {
        "dataset": ds,
        "index": spec.index,
        "spec": svc.generation.spec.to_dict(),
        "executor": executor,
        "max_batch": max_batch,
        "backend": backend,
        "request_keys": request_keys,
        "n_keys": int(len(keys)),
        "n_queries": int(len(q)),
        "n_shards": svc.dispatcher.n_shards,
        "build_s": round(build_s, 4),
        "lookups_per_s": round(snap["lookups_per_s"], 1),
        "mean_batch_ms": round(snap["mean_batch_ms"], 4),
        "p99_batch_ms": round(snap["p99_batch_ms"], 4),
        # latency decomposition (§13 observability): queue + batch ~=
        # request, so a p99 regression names its own culprit
        "p99_queue_ms": round(snap["p99_queue_ms"], 4),
        "mean_request_ms": round(snap["mean_request_ms"], 4),
        "p99_request_ms": round(snap["p99_request_ms"], 4),
        "cache_hit_rate": round(snap["cache_hit_rate"], 4),
        "warm_compiles": snap["warm_compiles"],
        "mean_inflight_slots": round(snap["mean_inflight_slots"], 3),
        "mean_occupancy": round(snap["mean_occupancy"], 4),
        "batches": snap["batches"],
        "verified_vs_core": verified,
    }
    return row, got


def run(out_dir: str = "benchmarks/results", backend=None, spec=None,
        autotune=None, executor: str = "both"):
    """Sweep the service.  ``spec`` pins ONE declarative IndexSpec for
    every cell; ``autotune`` (a byte budget) lets the `spec.Tuner` pick
    the per-dataset spec+backend instead of the serving defaults;
    ``executor`` picks one engine or "both" (the §13 A/B columns)."""
    from repro.serve.lookup import default_spec

    backend = backend or C.BACKEND
    executors = EXECUTORS if executor == "both" else [executor]
    rows = []
    for ds in DATASETS:
        if spec is not None:
            cells = [spec]
        elif autotune is not None:
            res = C.tuned_spec(ds, autotune, names=tuple(INDEX_NAMES),
                               backends=("jnp", "pallas"))
            cells = [res.spec]
        else:
            cells = [default_spec(i) for i in INDEX_NAMES]
        for sp in cells:
            be = sp.backend if (autotune is not None
                                and spec is None) else backend
            for max_batch, request_keys in BATCH_POINTS:
                for ex in executors:
                    r, _ = _run_cell(ds, sp, max_batch, request_keys,
                                     backend=be, executor=ex)
                    rows.append(r)
                    print(f"{ds:5s} {r['index']:12s} {ex:5s} "
                          f"batch={max_batch:5d} "
                          f"{r['lookups_per_s']/1e3:9.1f} klookups/s  "
                          f"p99_req={r['p99_request_ms']:8.2f}ms  "
                          f"hit={r['cache_hit_rate']:.2f}  occ="
                          f"{r['mean_occupancy']:.2f}  "
                          f"verified={r['verified_vs_core']}", flush=True)
    if executor == "both":
        _print_speedups(rows)
    path = os.path.join(out_dir, "serve_throughput.json")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {path}")
    n_bad = sum(not r["verified_vs_core"] for r in rows)
    if n_bad:
        raise SystemExit(f"{n_bad}/{len(rows)} cells NOT verified vs core")
    return rows


def _print_speedups(rows):
    """Per-cell sync/async p99 ratio — the §13 headline column."""
    cells = {}
    for r in rows:
        k = (r["dataset"], r["index"], r["max_batch"])
        cells.setdefault(k, {})[r["executor"]] = r
    ratios = []
    for (ds, ix, mb), pair in sorted(cells.items()):
        if "sync" not in pair or "async" not in pair:
            continue
        p_sync = pair["sync"]["p99_request_ms"]
        p_async = pair["async"]["p99_request_ms"]
        ratio = p_sync / p_async if p_async else float("inf")
        ratios.append(ratio)
        print(f"  p99 speedup {ds:5s} {ix:12s} batch={mb:5d}: "
              f"{p_sync:8.2f}ms -> {p_async:7.2f}ms  ({ratio:5.1f}x)",
              flush=True)
    if ratios:
        print(f"  p99 speedup median: {np.median(ratios):.1f}x  "
              f"(min {min(ratios):.1f}x, max {max(ratios):.1f}x)",
              flush=True)


def smoke(backend=None, executor: str = "async") -> None:
    """One tiny A/B cell, CI tripwire semantics: exit NONZERO when
    (a) the async executor's positions differ from the sync executor's
    by even one bit, (b) the warmed executable cache never hits under
    serving traffic, or (c) either engine diverges from the direct
    `repro.core` lookup."""
    from repro.serve.lookup import default_spec

    backend = backend or C.BACKEND
    sp = default_spec("rmi")
    row_s, got_s = _run_cell("amzn", sp, 512, 32, backend=backend,
                             executor="sync")
    row_a, got_a = _run_cell("amzn", sp, 512, 32, backend=backend,
                             executor=executor)
    for tag, row in (("sync", row_s), (executor, row_a)):
        print(f"  {tag:5s}: p99_req={row['p99_request_ms']:8.2f}ms  "
              f"p99_queue={row['p99_queue_ms']:8.2f}ms  "
              f"hit={row['cache_hit_rate']:.2f}  "
              f"verified={row['verified_vs_core']}", flush=True)
    if not np.array_equal(got_s, got_a):
        raise SystemExit(
            f"{executor} executor DIVERGED from sync: "
            f"{int(np.sum(got_s != got_a))}/{got_s.size} positions differ")
    if not (row_s["verified_vs_core"] and row_a["verified_vs_core"]):
        raise SystemExit("service positions diverged from repro.core")
    if executor == "async" and row_a["cache_hit_rate"] <= 0.0:
        raise SystemExit("async executable cache NEVER hit after warm-up")
    print(f"smoke ok: {executor} bit-identical to sync "
          f"({got_s.size} positions), cache hit rate "
          f"{row_a['cache_hit_rate']:.2f}", flush=True)


if __name__ == "__main__":
    _ns = C.bench_args()
    _ap = argparse.ArgumentParser(add_help=False)
    _ap.add_argument("--executor", choices=("sync", "async", "both"),
                     default="both")
    _ex = _ap.parse_known_args()[0].executor
    if _ns.smoke:
        smoke(backend=_ns.backend,
              executor="async" if _ex == "both" else _ex)
    else:
        run(backend=_ns.backend, spec=_ns.spec, autotune=_ns.autotune,
            executor=_ex)
