"""Paper Fig. 11 / §4.2.3: last-mile search functions.

Expectation from the paper: binary beats (vector-)linear at these bound
widths; interpolation helps on smooth data (amzn), not on osm.
"""
from __future__ import annotations

import os

from benchmarks import _common as C


def run(datasets=("amzn", "osm"), out_dir="benchmarks/results"):
    import jax.numpy as jnp
    from repro.core import base

    rows = []
    for ds in datasets:
        keys = C.dataset(ds)
        q = C.queries(ds)
        data_jnp, q_jnp = jnp.asarray(keys), jnp.asarray(q)
        for name, hyper in [("rmi", dict(branching=2048)),
                            ("pgm", dict(eps=128)),
                            ("radix_spline", dict(eps=64, radix_bits=14)),
                            ("rbs", dict(radix_bits=14))]:
            b = base.REGISTRY[name](keys, **hyper)
            for lm in ("binary", "linear", "interpolation"):
                fn = C.full_lookup_fn(b, data_jnp, last_mile=lm)
                secs = C.time_lookup(fn, q_jnp)
                rows.append([ds, name, lm,
                             round(C.ns_per_lookup(secs, len(q)), 2)])
    C.emit(rows, header=["dataset", "index", "last_mile", "ns_per_lookup"],
           path=os.path.join(out_dir, "search_fn.csv"))
    return rows


if __name__ == "__main__":
    run()
