"""Paper Fig. 11 / §4.2.3: last-mile search functions.

Expectation from the paper: binary beats (vector-)linear at these bound
widths; interpolation helps on smooth data (amzn), not on osm.

Beyond-paper axis: ``--backend pallas`` runs every cell through the
plan IR's kernel backend (`kernels/bounded_search`, fused
`kernels/rmi_lookup` for rmi; interpret mode on CPU) and asserts the LB
ranks match the jnp backend bit-for-bit — the CI smoke cell that keeps
kernel lowering from rotting.
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # `python benchmarks/search_fn.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks import _common as C


def run(datasets=("amzn", "osm"), out_dir="benchmarks/results",
        backend=None, spec=None):
    import numpy as np
    import jax.numpy as jnp
    from repro.core.spec import IndexSpec

    backend = backend or C.BACKEND
    cells = [spec] if spec is not None else [
        IndexSpec("rmi", dict(branching=2048)),
        IndexSpec("pgm", dict(eps=128)),
        IndexSpec("radix_spline", dict(eps=64, radix_bits=14)),
        IndexSpec("rbs", dict(radix_bits=14)),
    ]
    rows = []
    for ds in datasets:
        keys = C.dataset(ds)
        q = C.queries(ds)
        data_jnp, q_jnp = jnp.asarray(keys), jnp.asarray(q)
        lb = np.searchsorted(keys, q)
        for sp in cells:
            b = C.build_index(sp, keys)
            name = b.name
            for lm in ("binary", "linear", "interpolation"):
                fn = C.full_lookup_fn(b, data_jnp, last_mile=lm,
                                      backend=backend)
                secs = C.time_lookup(fn, q_jnp)
                if backend != "jnp":
                    got = np.asarray(fn(q_jnp))
                    assert (got == lb).all(), \
                        f"{backend} backend diverged: {ds}/{name}/{lm}"
                rows.append([ds, name, lm, backend,
                             round(C.ns_per_lookup(secs, len(q)), 2)])
    C.emit(rows, header=["dataset", "index", "last_mile", "backend",
                         "ns_per_lookup"],
           path=os.path.join(out_dir, "search_fn.csv"))
    return rows


if __name__ == "__main__":
    ns = C.bench_args(sys.argv[1:])
    run(backend=ns.backend, spec=ns.spec)
