"""Paper-technique-in-framework table: MoE dispatch modes compared.

The paper's lower_bound machinery powers the `sorted` dispatch; the
GShard-style `dense` mode is the no-index baseline (every expert computes
every token).  Compared on (a) compiled dot-FLOPs of a smoke train step
(via the trip-count-aware analyzer) and (b) measured CPU step time.
This is the end-to-end 'does the paper's technique pay inside a real
system' table the paper's conclusion asks for.
"""
from __future__ import annotations

import dataclasses
import os
import time

from benchmarks import _common as C
from benchmarks import hlo_cost


def run(out_dir="benchmarks/results"):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.models import model as M

    rows = []
    for arch in ("deepseek-moe-16b", "mixtral-8x22b"):
        for mode in ("sorted", "dense"):
            cfg = dataclasses.replace(get_smoke(arch), moe_dispatch=mode,
                                      n_experts=16, top_k=2)
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            batch = {"tokens": jnp.ones((4, 128), jnp.int32),
                     "labels": jnp.ones((4, 128), jnp.int32)}
            fn = jax.jit(
                lambda p, b: jax.value_and_grad(
                    lambda pp: M.loss_fn(cfg, pp, b))(p))
            compiled = fn.lower(params, batch).compile()
            flops = hlo_cost.analyze(compiled.as_text())["flops"]
            out = fn(params, batch)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            jax.block_until_ready(fn(params, batch))
            dt = time.perf_counter() - t0
            rows.append([arch, mode, f"{flops:.3e}", round(dt * 1e3, 1)])
    # derived: flop ratio dense/sorted per arch
    for arch in ("deepseek-moe-16b", "mixtral-8x22b"):
        fs = {r[1]: float(r[2]) for r in rows if r[0] == arch}
        rows.append([arch, "dense/sorted-flop-ratio",
                     round(fs["dense"] / fs["sorted"], 2), ""])
    C.emit(rows, header=["arch", "dispatch", "train_step_dot_flops",
                         "cpu_step_ms"],
           path=os.path.join(out_dir, "moe_dispatch.csv"))
    return rows


if __name__ == "__main__":
    run()
