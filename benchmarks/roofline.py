"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell on the single-pod 16x16 mesh:

  compute term    = dot_flops_per_device / PEAK_FLOPS_BF16
  memory term     = hbm_bytes_per_device / HBM_BW
  collective term = collective_bytes_per_device / (LINKS * ICI_BW)

dot_flops and collective bytes come from the trip-count-aware HLO analyzer
(benchmarks/hlo_cost.py) over the compiled per-device program.  The memory
term uses per-device buffer capacity touched (args + outputs + temps, each
counted once — a traffic LOWER bound; the CPU backend also upcasts some
bf16 buffers to f32, so it is quoted as 'pessimistic capacity', see
EXPERIMENTS.md §Dry-run caveats).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per device; the ratio
MODEL_FLOPS / HLO_FLOPs flags remat/redundancy waste.
"""
from __future__ import annotations

import json
import os
import sys

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
ICI_LINKS = 4  # usable links per v5e chip (2D torus: 4 directions)


def terms(rec, chips: int = 256):
    flops = rec["dot_flops_per_device"]
    coll = rec["collective_bytes_total"]
    mem = rec["memory"]
    hbm_bytes = (mem["argument_bytes"] + mem["output_bytes"]
                 + mem["temp_bytes"])
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll / (ICI_LINKS * ICI_BW)
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    # model flops per device: fwd+bwd for train, fwd for prefill, per-token
    # for decode
    n_active = rec["active_params"]
    tokens = rec["tokens"]
    if rec["kind"] == "train":
        model_flops = 6.0 * n_active * tokens / chips
    elif rec["kind"] == "prefill":
        model_flops = 2.0 * n_active * tokens / chips
    else:  # decode: one token per sequence
        model_flops = 2.0 * n_active * tokens / chips
    ratio = model_flops / flops if flops else float("nan")
    bound = max(t_compute, t_memory, t_coll)
    # roofline fraction: useful-compute time / bound time
    frac = (model_flops / PEAK_FLOPS_BF16) / bound if bound else 0.0
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": model_flops,
        "useful_ratio": ratio,
        "roofline_fraction": frac,
    }


def load(path="benchmarks/results/dryrun_single_pod.json"):
    with open(path) as f:
        return json.load(f)


def table(path="benchmarks/results/dryrun_single_pod.json", md=False):
    rows = []
    for rec in load(path):
        if rec.get("status") != "ok":
            rows.append([rec["arch"], rec["shape"], rec.get("status"),
                         rec.get("reason", rec.get("error", ""))[:60],
                         "", "", "", "", ""])
            continue
        t = terms(rec)
        rows.append([
            rec["arch"], rec["shape"], t["dominant"],
            f"{t['t_compute_s']*1e3:.2f}", f"{t['t_memory_s']*1e3:.2f}",
            f"{t['t_collective_s']*1e3:.2f}",
            f"{t['useful_ratio']:.2f}", f"{t['roofline_fraction']:.3f}",
            f"{rec['memory']['temp_bytes']/2**30:.1f}",
        ])
    header = ["arch", "shape", "dominant", "t_comp_ms", "t_mem_ms",
              "t_coll_ms", "useful/hlo", "roofline_frac", "temp_GiB"]
    if md:
        out = ["| " + " | ".join(header) + " |",
               "|" + "---|" * len(header)]
        out += ["| " + " | ".join(str(c) for c in r) + " |" for r in rows]
        return "\n".join(out)
    out = [",".join(header)] + [",".join(str(c) for c in r) for r in rows]
    return "\n".join(out)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "benchmarks/results/dryrun_single_pod.json"
    print(table(path, md="--md" in sys.argv))
